#!/usr/bin/env python3
"""Offline analysis of a repro.obs Chrome-trace JSON (``--trace-out``).

Standalone on purpose — stdlib only, no ``repro`` import — so CI can run
it on an uploaded trace artifact without the package or its toolchain:

  python tools/trace_report.py t.json             # human-readable report
  python tools/trace_report.py t.json --validate  # schema check, exit != 0
  python tools/trace_report.py t.json --json      # the report as JSON

What it derives, from the trace alone:

  * **schema validation** — every event well-formed for its phase type,
    every pid/tid backed by a metadata name event, spans non-negative
    and non-overlapping per track (the exporter lane-packs AMU tracks
    precisely so this holds),
  * **SLO report reproduction** — per-tier attainment/goodput/TTFT
    percentiles recomputed from the request-lifecycle ``finish``
    instants; must equal the engine's own ``slo_report()`` (asserted in
    ``tests/test_obs.py``),
  * **queueing-delay breakdown per QoS** — where each AMU transfer's
    wall time went: waiting in the pager's QoS window queue
    (``window_wait_us``), blocked on a free device frame
    (``frame_blocked_us``), queued for an AMU slot (``queued_us``), and
    actually in flight (span duration minus slot wait),
  * **window occupancy / lifecycle counts** — peak per-QoS occupancy
    from the counter tracks, preempt/resume/shed instants,
  * **speculation accounting** — drafted/accepted/rejected totals from
    the cumulative ``spec_*`` counter tracks (validated monotone and
    self-consistent) and mean accepted-K from the per-step ``verify``
    instants; must equal the engine's own stats (asserted in
    ``tests/test_obs.py``).
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Any, Dict, List, Tuple

PHASES = {"M", "X", "i", "C"}


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def percentile(xs: List[float], q: float) -> float:
    """numpy.percentile(xs, q) with the default linear interpolation."""
    if not xs:
        return 0.0
    s = sorted(xs)
    rank = (q / 100.0) * (len(s) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(s) - 1)
    frac = rank - lo
    return s[lo] * (1.0 - frac) + s[hi] * frac


def track_names(events: List[dict]) -> Tuple[Dict[int, str],
                                             Dict[Tuple[int, int], str]]:
    """pid -> process name, (pid, tid) -> thread name from "M" events."""
    pids: Dict[int, str] = {}
    tids: Dict[Tuple[int, int], str] = {}
    for ev in events:
        if ev.get("ph") != "M":
            continue
        if ev.get("name") == "process_name":
            pids[ev["pid"]] = ev["args"]["name"]
        elif ev.get("name") == "thread_name":
            tids[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    return pids, tids


# -- validation ---------------------------------------------------------------

def validate(doc: Any) -> List[str]:
    """Schema problems (empty list == valid Chrome-trace JSON)."""
    probs: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document is not a dict with a traceEvents list"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    pids, tids = track_names(events)
    spans: Dict[Tuple[int, int], List[Tuple[float, float, str]]] = {}
    for i, ev in enumerate(events):
        where = f"event {i}"
        if not isinstance(ev, dict):
            probs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in PHASES:
            probs.append(f"{where}: bad ph {ph!r}")
            continue
        for key in ("pid", "tid", "name"):
            if key not in ev:
                probs.append(f"{where}: missing {key}")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            probs.append(f"{where}: bad ts {ts!r}")
            continue
        if ev["pid"] not in pids:
            probs.append(f"{where}: pid {ev['pid']} has no process_name")
        elif (ev["pid"], ev["tid"]) not in tids:
            probs.append(f"{where}: tid {ev['tid']} has no thread_name")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                probs.append(f"{where}: bad dur {dur!r}")
            else:
                spans.setdefault((ev["pid"], ev["tid"]), []).append(
                    (ts, dur, ev.get("name", "?")))
        elif ph == "i":
            if ev.get("s") not in ("t", "p", "g"):
                probs.append(f"{where}: instant missing scope s")
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                probs.append(f"{where}: counter without a value")
    # speculation counter tracks are cumulative: samples must be
    # non-decreasing, and the final accepted + rejected must equal the
    # final drafted (the engine's own accounting identity)
    spec_last: Dict[str, float] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or ev.get("ph") != "C":
            continue
        name = str(ev.get("name", ""))
        if not name.startswith("spec_"):
            continue
        v = float(ev.get("args", {}).get("value", 0.0))
        if v < spec_last.get(name, 0.0):
            probs.append(f"event {i}: cumulative counter {name} went "
                         f"backwards ({spec_last[name]:.0f} -> {v:.0f})")
        spec_last[name] = v
    if spec_last:
        d, a, r = (spec_last.get(f"spec_{k}", 0.0)
                   for k in ("drafted", "accepted", "rejected"))
        if abs(a + r - d) > 0.5:
            probs.append(f"speculation accounting broken: accepted {a:.0f}"
                         f" + rejected {r:.0f} != drafted {d:.0f}")
    # complete spans on one thread must nest/abut, never overlap (the
    # exporter lane-packs the AMU tracks to guarantee this)
    for (pid, tid), sp in spans.items():
        sp.sort()
        open_end = -math.inf
        for ts, dur, name in sp:
            if ts < open_end - 1e-3 and ts + dur > open_end + 1e-3:
                track = tids.get((pid, tid), f"{pid}/{tid}")
                probs.append(
                    f"track {track}: span {name!r} at ts={ts:.1f} "
                    f"overlaps the previous span ending {open_end:.1f}")
            open_end = max(open_end, ts + dur)
    return probs


# -- SLO report reproduction --------------------------------------------------

def report_from_trace(doc: dict) -> Dict[str, Any]:
    """Rebuild the engine's ``slo_report()`` from lifecycle instants."""
    events = doc["traceEvents"]
    pids, _ = track_names(events)
    elapsed = max(float(doc.get("otherData", {}).get("clock_s", 0.0)), 1e-12)
    by_tier: Dict[str, List[dict]] = {}
    for ev in events:
        if ev.get("ph") == "i" and ev.get("name") == "finish" \
                and pids.get(ev["pid"]) == "requests":
            a = ev.get("args", {})
            by_tier.setdefault(str(a.get("tier", "?")).lower(), []).append(a)
    out: Dict[str, Any] = {"elapsed": elapsed}
    for tier in ("interactive", "batch"):
        rows = by_tier.get(tier, [])
        ttfts = [float(a["first_token"]) - float(a["arrival"])
                 for a in rows if a.get("n_new", 0) > 0]
        good = [a for a in rows if a.get("attained")]
        good_tokens = sum(int(a.get("n_new", 0)) for a in good)
        out[tier] = {
            "n": len(rows),
            "attained": len(good),
            "attainment": len(good) / len(rows) if rows else 1.0,
            "good_tokens": good_tokens,
            "goodput": good_tokens / elapsed,
            "ttft_p50": percentile(ttfts, 50),
            "ttft_p95": percentile(ttfts, 95),
            "ttft_p99": percentile(ttfts, 99),
        }
    return out


# -- AMU queueing-delay breakdown ---------------------------------------------

def amu_breakdown(doc: dict) -> Dict[str, Dict[str, float]]:
    """Per-QoS decomposition of every AMU transfer's wall time."""
    events = doc["traceEvents"]
    pids, tids = track_names(events)
    out: Dict[str, Dict[str, float]] = {}
    for ev in events:
        if ev.get("ph") != "X" or pids.get(ev["pid"]) != "amu":
            continue
        lane = tids.get((ev["pid"], ev["tid"]), "?")
        qos = lane.split("·")[0]        # strip the ·N lane suffix
        args = ev.get("args", {})
        row = out.setdefault(qos, {
            "n": 0, "bytes": 0.0, "total_us": 0.0, "queued_us": 0.0,
            "in_flight_us": 0.0, "window_wait_us": 0.0,
            "frame_blocked_us": 0.0, "faults": 0})
        queued = float(args.get("queued_us", 0.0))
        row["n"] += 1
        row["bytes"] += float(args.get("nbytes", 0.0))
        row["total_us"] += ev["dur"]
        row["queued_us"] += queued
        row["in_flight_us"] += max(0.0, ev["dur"] - queued)
        row["window_wait_us"] += float(args.get("window_wait_us", 0.0))
        row["frame_blocked_us"] += float(args.get("frame_blocked_us", 0.0))
    for ev in events:
        if ev.get("ph") == "i" and ev.get("name") == "fault" \
                and pids.get(ev["pid"]) == "amu":
            qos = tids.get((ev["pid"], ev["tid"]), "?").split("·")[0]
            if qos in out:
                out[qos]["faults"] += 1
    return out


def occupancy_peaks(doc: dict) -> Dict[str, float]:
    """Peak value of every counter track (per-QoS window occupancy)."""
    peaks: Dict[str, float] = {}
    for ev in doc["traceEvents"]:
        if ev.get("ph") == "C":
            v = float(ev.get("args", {}).get("value", 0.0))
            name = ev.get("name", "?")
            peaks[name] = max(peaks.get(name, 0.0), v)
    return peaks


def speculation_report(doc: dict) -> Dict[str, Any]:
    """Acceptance accounting from the engine's speculation tracks.

    The ``spec_*`` counter tracks are cumulative, and the exporter
    drops samples equal to the previous one — so totals are read off
    the LAST emitted sample per track (which always carries the final
    value: any change is emitted).  The per-step ``verify`` instants
    carry each step's deltas and give the mean accepted-K."""
    events = doc["traceEvents"]
    pids, _ = track_names(events)
    last: Dict[str, float] = {}
    steps: List[dict] = []
    for ev in events:
        if pids.get(ev.get("pid")) != "engine":
            continue
        if ev.get("ph") == "C" and str(ev.get("name", "")).startswith("spec_"):
            last[ev["name"]] = float(ev.get("args", {}).get("value", 0.0))
        elif ev.get("ph") == "i" and ev.get("name") == "verify":
            steps.append(ev.get("args", {}))
    if not steps and not last:
        return {}
    drafted = last.get("spec_drafted", 0.0)
    accepted = last.get("spec_accepted", 0.0)
    rejected = last.get("spec_rejected", 0.0)
    return {
        "verify_steps": len(steps),
        "drafted": int(drafted),
        "accepted": int(accepted),
        "rejected": int(rejected),
        "mean_accepted_k": (sum(float(a.get("accepted", 0)) for a in steps)
                            / len(steps)) if steps else 0.0,
        "consistent": abs(accepted + rejected - drafted) < 0.5,
    }


def lifecycle_counts(doc: dict) -> Dict[str, int]:
    """How many of each pager/engine/request instant the trace holds."""
    pids, _ = track_names(doc["traceEvents"])
    counts: Dict[str, int] = {}
    for ev in doc["traceEvents"]:
        if ev.get("ph") in ("i", "X") and pids.get(ev["pid"]) != "amu":
            key = f"{pids.get(ev['pid'], '?')}/{ev.get('name', '?')}"
            counts[key] = counts.get(key, 0) + 1
    return counts


def build_report(doc: dict) -> Dict[str, Any]:
    return {
        "slo": report_from_trace(doc),
        "amu_qos": amu_breakdown(doc),
        "counter_peaks": occupancy_peaks(doc),
        "speculation": speculation_report(doc),
        "lifecycle": lifecycle_counts(doc),
        "open_spans_flushed": doc.get("otherData", {})
                                 .get("open_spans_flushed", 0),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Analyse a repro.obs Chrome-trace JSON")
    ap.add_argument("trace", help="path to a --trace-out JSON file")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check only; exit non-zero on problems")
    ap.add_argument("--json", action="store_true",
                    help="emit the full report as JSON on stdout")
    args = ap.parse_args(argv)

    doc = load(args.trace)
    probs = validate(doc)
    if args.validate:
        if probs:
            for p in probs:
                print(f"INVALID: {p}", file=sys.stderr)
            return 1
        n = len(doc["traceEvents"])
        print(f"OK: {n} events, "
              f"{doc.get('otherData', {}).get('open_spans_flushed', 0)} "
              "open spans flushed")
        return 0
    if probs:
        for p in probs:
            print(f"warning: {p}", file=sys.stderr)

    rep = build_report(doc)
    if args.json:
        json.dump(rep, sys.stdout, indent=2, sort_keys=True)
        print()
        return 0

    slo = rep["slo"]
    print(f"elapsed (virtual): {slo['elapsed'] * 1e3:.2f} ms")
    for tier in ("interactive", "batch"):
        t = slo[tier]
        print(f"  {tier}: n={t['n']} attainment={t['attainment']:.2f} "
              f"goodput={t['goodput']:.1f} tok/s "
              f"ttft p50/p95/p99 = {t['ttft_p50'] * 1e3:.2f}/"
              f"{t['ttft_p95'] * 1e3:.2f}/{t['ttft_p99'] * 1e3:.2f} ms")
    if rep["amu_qos"]:
        print("AMU transfers by QoS (means per transfer):")
        for qos, r in sorted(rep["amu_qos"].items()):
            n = max(1, r["n"])
            print(f"  {qos}: n={r['n']} "
                  f"window_wait={r['window_wait_us'] / n:.1f}us "
                  f"frame_blocked={r['frame_blocked_us'] / n:.1f}us "
                  f"amu_queue={r['queued_us'] / n:.1f}us "
                  f"in_flight={r['in_flight_us'] / n:.1f}us "
                  f"faults={r['faults']}")
    if rep["speculation"]:
        sp = rep["speculation"]
        print(f"speculation: steps={sp['verify_steps']} "
              f"drafted={sp['drafted']} accepted={sp['accepted']} "
              f"rejected={sp['rejected']} "
              f"mean accepted-K={sp['mean_accepted_k']:.2f}"
              + ("" if sp["consistent"] else "  [INCONSISTENT]"))
    if rep["counter_peaks"]:
        peaks = ", ".join(f"{k}={v:.0f}"
                          for k, v in sorted(rep["counter_peaks"].items()))
        print(f"counter peaks: {peaks}")
    interesting = {k: v for k, v in sorted(rep["lifecycle"].items())
                   if not k.startswith("requests/")}
    if interesting:
        print("pager/engine events: "
              + ", ".join(f"{k}={v}" for k, v in interesting.items()))
    return 0


if __name__ == "__main__":
    sys.exit(main())

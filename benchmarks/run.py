"""Benchmark harness — one function per paper table/figure + system benches.

The paper (a white paper) has one figure and three quantitative claims;
each gets a bench:

  * fig1_latency_sweep — blocking vs AMU bandwidth across the 300ns-10us
    far-memory band (THE figure),
  * granularity_sweep  — variable-granularity claim (§1, Fig 1 right),
  * outstanding_sweep  — MLP vs ROB/MSHR-limited window (§1),
  * paged_kv_sweep     — repro.paging pager vs blocking whole-sequence KV
                         fetch across oversubscription ratios (hit rate,
                         us/token; the serving-capacity claim),
  * mixed_batch_sweep  — chunked continuous batching (mixed prefill+decode
                         steps) vs serial dense prefill across request
                         oversubscription: mean/p95 TTFT + decode tok/s
                         (the admission-bubble claim),
  * disagg_sweep      — disaggregated prefill/decode over one shared far
                         tier vs two fused mixed-step engines at matched
                         device counts: TTFT / inter-token latency /
                         goodput ratios across request oversubscription
                         (the interference-isolation claim; TPOT is the
                         acceptance axis, the TTFT/goodput columns record
                         what the split costs),
  * prefix_reuse_sweep — cross-request prefix sharing vs recompute across
                         shared-traffic fractions at 2x oversubscription:
                         TTFT speedup + prefill FLOPs saved (the
                         system-prompt reuse claim),
  * spec_decode_sweep  — self-speculative verify-K decode vs single-step
                         across request oversubscription for repetitive
                         and adversarial traffic: decode tok/s speedup +
                         mean accepted-K (the draft-free speculation
                         claim; the repetitive 2x row is gated >= 1.3x),
  * slo_goodput_sweep  — SLO-aware scheduling (EDF + batch shedding +
                         max-slack preemption onto QoS windows) vs
                         watermark-FIFO on one production trace across
                         request oversubscription: interactive goodput
                         ratio + per-tier SLO attainment (the goodput
                         claim),
  * obs_overhead       — telemetry observer-effect guard (repro.obs):
                         the paged_kv_sweep 2x sim untraced /
                         tracer-disabled / tracer-enabled must agree on
                         the virtual clock (disabled == committed
                         baseline exactly, enabled < 10% drift — gated
                         in CI); wall-clock cost rides along as
                         ``wall_frac``,
  * amu_runtime        — software-AMU issue/getfin overhead (runtime path),
  * kernels            — per-kernel interpret-mode us_per_call (semantic
    cost on CPU; real perf comes from the dry-run roofline, not this),
  * roofline           — reads dryrun_*.jsonl and emits the per-cell
    three-term roofline table.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract.

``--smoke`` runs a fast subset (sim sweeps + runtime overhead; skips the
interpret-mode kernel timings) for CI; ``--json PATH`` additionally
writes the rows as JSON so each CI run archives a ``BENCH_*.json``
artifact and the perf trajectory accumulates across commits.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

_ROWS: list = []


def _row(name: str, us: float, derived: str = "") -> None:
    _ROWS.append({"name": name, "us_per_call": round(us, 2),
                  "derived": derived})
    print(f"{name},{us:.2f},{derived}")


# ---------------------------------------------------------------------------
# paper figure 1
# ---------------------------------------------------------------------------

def bench_fig1_latency_sweep() -> None:
    from repro.core.sim import bandwidth_sweep
    lats = [100e-9, 200e-9, 300e-9, 1e-6, 3e-6, 10e-6]
    t0 = time.perf_counter()
    rows = bandwidth_sweep(lats, total_bytes=1 << 24)
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    for r in rows:
        _row("fig1_latency_sweep", us,
             f"lat={r['latency_s']*1e9:.0f}ns sync_util={r['sync_util']:.4f} "
             f"amu_util={r['amu_util']:.4f} speedup={r['speedup']:.1f}")


def bench_granularity_sweep() -> None:
    from repro.core.sim import AMUParams, LatencyModel, simulate_amu
    lm = LatencyModel("fixed", 3e-6, 3e-6)
    for g in (64, 256, 1024, 4096, 16384):
        t0 = time.perf_counter()
        r = simulate_amu(1 << 24, lm, AMUParams(outstanding=64, granularity=g))
        us = (time.perf_counter() - t0) * 1e6
        _row("granularity_sweep", us,
             f"granularity={g}B util={r.utilization:.4f} "
             f"bw={r.achieved_bw/1e9:.2f}GB/s")


def bench_outstanding_sweep() -> None:
    from repro.core.sim import AMUParams, LatencyModel, simulate_amu
    lm = LatencyModel("fixed", 3e-6, 3e-6)
    for q in (4, 16, 64, 256, 1024):
        t0 = time.perf_counter()
        r = simulate_amu(1 << 24, lm, AMUParams(outstanding=q,
                                                granularity=1024))
        us = (time.perf_counter() - t0) * 1e6
        _row("outstanding_sweep", us,
             f"outstanding={q} util={r.utilization:.4f} mlp={r.mean_mlp:.1f}")


def bench_paged_kv_sweep() -> None:
    """repro.paging: AMU prefetching pager vs blocking whole-sequence KV
    fetch, swept over device-pool oversubscription (SimBackend, fully
    deterministic).  Tracks the hit rate and us/token of the paging
    path in CI; the 2x row is the subsystem's acceptance number.

    ``speedup`` is decode computing on the paged layout directly (the
    engine's current path — zero densification); ``densify`` is the same
    pager with the old per-activation join/insert round-trip added, so
    the delta is what eliminating dense KV re-materialisation buys."""
    from repro.paging.sim import simulate_paged_serving
    for oversub in (1.0, 1.5, 2.0, 4.0, 8.0):
        t0 = time.perf_counter()
        r = simulate_paged_serving(oversub)
        us = (time.perf_counter() - t0) * 1e6
        _row("paged_kv_sweep", us,
             f"oversub={oversub:g} pool={r['pool_pages']}pg "
             f"speedup={r['speedup']:.2f} hit_rate={r['hit_rate']:.3f} "
             f"blocking={r['blocking_us_per_token']:.2f}us/tok "
             f"paged={r['paged_us_per_token']:.2f}us/tok "
             f"densify={r['paged_densify_us_per_token']:.2f}us/tok "
             f"densify_speedup={r['speedup_densify']:.2f} "
             f"bulk_wb={r['bulk_writebacks']} demand={r['demand_fetches']}")


def bench_mixed_batch_sweep() -> None:
    """Chunked continuous batching vs serial dense prefill (deterministic
    virtual clock): a burst of ``oversub * slots * 4`` requests served
    through mixed prefill+decode steps versus admit-then-stall dense
    prefill.  The 2x row is the chunk-queue engine's acceptance number:
    mean time-to-first-token must improve without the decode stream
    regressing.  Pages are not the constraint here (that is
    ``paged_kv_sweep``); this isolates the admission bubble."""
    from repro.paging.sim import simulate_mixed_batching
    for oversub in (0.5, 1.0, 2.0, 4.0):
        t0 = time.perf_counter()
        r = simulate_mixed_batching(oversub)
        us = (time.perf_counter() - t0) * 1e6
        _row("mixed_batch_sweep", us,
             f"oversub={oversub:g} ttft_dense={r['ttft_dense_us']:.0f}us "
             f"ttft_mixed={r['ttft_mixed_us']:.0f}us "
             f"ttft_speedup={r['ttft_speedup']:.3f} "
             f"ttft_p95_mixed={r['ttft_p95_mixed_us']:.0f}us "
             f"tok_dense={r['tok_per_s_dense']:.0f}/s "
             f"tok_mixed={r['tok_per_s_mixed']:.0f}/s "
             f"thr_speedup={r['throughput_speedup']:.3f}")


def bench_disagg_sweep() -> None:
    """Disaggregated prefill/decode over one shared far tier vs two
    fused mixed-step engines at matched device counts (deterministic
    virtual clock; repro.paging.sim.simulate_disagg).  Both sides serve
    the same burst on two devices; the disaggregated side pays a BULK
    handoff park + LATENCY admission fetch per request and serialises
    every prompt through one prefill device, but its decode device's
    steps are never stretched by chunk work.  The committed acceptance
    axis is ``tpot_ratio`` (fused mean inter-token latency over
    disaggregated — the interference disaggregation removes); the
    ``ttft_ratio`` / ``goodput_ratio`` columns record honestly what
    the split costs on this workload shape."""
    from repro.paging.sim import simulate_disagg
    for oversub in (0.5, 1.0, 2.0, 4.0):
        t0 = time.perf_counter()
        r = simulate_disagg(oversub)
        us = (time.perf_counter() - t0) * 1e6
        _row("disagg_sweep", us,
             f"oversub={oversub:g} n_seqs={r['n_seqs']:.0f} "
             f"xfer={r['handoff_xfer_us']:.0f}us "
             f"ttft_fused={r['ttft_fused_us']:.0f}us "
             f"ttft_disagg={r['ttft_disagg_us']:.0f}us "
             f"ttft_ratio={r['ttft_ratio']:.3f} "
             f"tpot_fused={r['tpot_fused_us']:.2f}us "
             f"tpot_disagg={r['tpot_disagg_us']:.2f}us "
             f"tpot_ratio={r['tpot_ratio']:.3f} "
             f"tok_fused={r['tok_per_s_fused']:.0f}/s "
             f"tok_disagg={r['tok_per_s_disagg']:.0f}/s "
             f"goodput_ratio={r['goodput_ratio']:.3f}")


def bench_spec_decode_sweep() -> None:
    """Self-speculative verify-K decode vs single-step (deterministic
    virtual clock; repro.paging.sim.simulate_spec_decode), swept over
    request oversubscription for two traffic shapes.  Drafting uses the
    real NgramProposer over synthetic streams; a verify step costs
    ``t_decode_step * (1 + 0.15 * K)`` and advances ``1 + accepted``.
    The repetitive 2x row is the acceptance number: >= 1.3x decode
    throughput (the draft-free speculation claim).  The adversarial
    rows (i.i.d. tokens over a small alphabet, so the prompt-lookup
    index fires spuriously and verification rejects nearly all of it)
    record honestly what mis-drafting costs."""
    from repro.paging.sim import simulate_spec_decode
    for traffic, vocab in (("repetitive", 512), ("adversarial", 16)):
        for oversub in (0.5, 1.0, 2.0, 4.0):
            t0 = time.perf_counter()
            r = simulate_spec_decode(oversub, traffic=traffic, vocab=vocab)
            us = (time.perf_counter() - t0) * 1e6
            _row("spec_decode_sweep", us,
                 f"traffic={traffic} oversub={oversub:g} "
                 f"n_seqs={r['n_seqs']:.0f} vocab={vocab} "
                 f"tok_plain={r['tok_per_s_plain']:.0f}/s "
                 f"tok_spec={r['tok_per_s_spec']:.0f}/s "
                 f"thr_speedup={r['throughput_speedup']:.3f} "
                 f"drafted={r['drafted']:.0f} "
                 f"accepted={r['accepted']:.0f} "
                 f"mean_accepted_k={r['mean_accepted_k']:.2f} "
                 f"acceptance={r['acceptance_rate']:.3f}")


def bench_prefix_reuse_sweep() -> None:
    """Cross-request prefix sharing (repro.paging.prefix_cache policy)
    vs recompute-everything, swept over the shared-traffic fraction at
    2x request oversubscription (deterministic virtual clock).  The
    50% row is the acceptance number: mean TTFT must improve >= 1.5x
    when half the burst carries the same system prompt, with the
    prefill-FLOPs column showing what the fleet stopped recomputing."""
    from repro.paging.sim import simulate_prefix_reuse
    for frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        t0 = time.perf_counter()
        r = simulate_prefix_reuse(frac)
        us = (time.perf_counter() - t0) * 1e6
        _row("prefix_reuse_sweep", us,
             f"shared={frac:g} oversub={r['oversubscription']:g} "
             f"hit_tokens={r['hit_tokens']} "
             f"ttft_plain={r['ttft_plain_us']:.0f}us "
             f"ttft_shared={r['ttft_shared_us']:.0f}us "
             f"ttft_speedup={r['ttft_speedup']:.3f} "
             f"flops_saved={r['prefill_flops_saved_frac']:.3f} "
             f"far_hits={r['far_hits']}")


def bench_slo_goodput_sweep() -> None:
    """SLO-aware scheduling vs watermark-FIFO utilisation scheduling on
    one production workload trace (repro.serve.workload: bursty diurnal
    arrivals, lognormal/Zipf lengths, interactive-vs-batch tiers),
    swept over request oversubscription (deterministic virtual clock).
    The 4x row is the scheduler's acceptance number: the SLO policy
    must deliver >= 1.2x the interactive goodput of watermark-FIFO
    when the system is drowning — goodput counts only tokens from
    requests that met their own TTFT/TPOT SLOs."""
    from repro.paging.sim import simulate_slo_schedule
    for oversub in (1.0, 2.0, 3.0, 4.0):
        t0 = time.perf_counter()
        r = simulate_slo_schedule(oversub)
        us = (time.perf_counter() - t0) * 1e6
        _row("slo_goodput_sweep", us,
             f"oversub={oversub:g} "
             f"goodput_ratio={r['goodput_ratio']:.3f} "
             f"goodput_wm={r['int_goodput_wm']:.0f}tok/s "
             f"goodput_slo={r['int_goodput_slo']:.0f}tok/s "
             f"attain_wm={r['int_attain_wm']:.3f} "
             f"attain_slo={r['int_attain_slo']:.3f} "
             f"ttft_p95_wm={r['ttft_p95_wm_us']:.0f}us "
             f"ttft_p95_slo={r['ttft_p95_slo_us']:.0f}us "
             f"ttft_p99_wm={r['ttft_p99_wm_us']:.0f}us "
             f"ttft_p99_slo={r['ttft_p99_slo_us']:.0f}us "
             f"preempts={r['preemptions_slo']:.0f} "
             f"sheds={r['shed_admissions_slo']:.0f}")


def bench_obs_overhead(trace_out=None, metrics_out=None,
                       repeats: int = 3) -> None:
    """Telemetry observer-effect guard (repro.obs, PR 7).

    Runs the exact ``paged_kv_sweep`` 2x-oversubscription sim three
    ways — untraced, tracer-disabled (``Tracer(enabled=False)``
    threaded through every instrumentation site), tracer-enabled — and
    reports the *virtual-clock* throughput of each.  Telemetry observes
    the simulation and must never perturb it: ``check_regression.py``
    fails when the disabled run's ``paged_off`` drifts from the
    committed baseline's ``paged_kv_sweep oversub=2`` row at all, or
    the enabled run's ``paged_on`` degrades it more than 10% (in
    practice 0% — the tracer never touches the clock).  Wall-clock cost
    (``wall_frac``, min-of-``repeats``) is reported for the perf
    trajectory but not hard-gated: CI boxes are too noisy for a
    wall-time ceiling, while the virtual numbers are exact.

    With ``trace_out``/``metrics_out`` set, the enabled run's trace and
    metrics snapshot are written out — the CI artifact that
    ``tools/trace_report.py --validate`` checks."""
    from repro.obs import (MetricsRegistry, Tracer, write_chrome_trace,
                           write_metrics)
    from repro.paging.sim import simulate_paged_serving

    def run_once(tracer, metrics):
        t0 = time.perf_counter()
        r = simulate_paged_serving(2.0, tracer=tracer, metrics=metrics)
        return time.perf_counter() - t0, r

    base_s = on_s = float("inf")
    r_base = r_off = r_on = None
    last = None
    for _ in range(repeats):
        s, r_base = run_once(None, None)
        base_s = min(base_s, s)
        _, r_off = run_once(Tracer(enabled=False), None)
        tr, mx = Tracer(enabled=True), MetricsRegistry()
        s, r_on = run_once(tr, mx)
        on_s = min(on_s, s)
        last = (tr, mx)
    det = all(r_base[k] == r_off[k] == r_on[k]
              for k in ("paged_us_per_token", "hit_rate", "demand_fetches",
                        "bulk_writebacks"))
    wall_frac = max(0.0, on_s / base_s - 1.0)
    tr, mx = last
    _row("obs_overhead", on_s * 1e6,
         f"paged_base={r_base['paged_us_per_token']:.2f} "
         f"paged_off={r_off['paged_us_per_token']:.2f} "
         f"paged_on={r_on['paged_us_per_token']:.2f} "
         f"deterministic={int(det)} events={len(tr.events)} "
         f"wall_base={base_s*1e6:.0f}us wall_on={on_s*1e6:.0f}us "
         f"wall_frac={wall_frac:.3f}")
    if trace_out:
        write_chrome_trace(trace_out, tr, mx)
    if metrics_out:
        write_metrics(metrics_out, mx)


# ---------------------------------------------------------------------------
# AMU software runtime overhead
# ---------------------------------------------------------------------------

def bench_amu_runtime(n: int = 20_000) -> None:
    from repro.core.amu import AMU, SimBackend
    # 256 outstanding slots = a realistic hardware queue; completion
    # polling is O(in_flight) per issue, and in_flight <= max_outstanding.
    amu = AMU(backend=SimBackend(base_latency=0.0, bandwidth=1e15),
              max_outstanding=256)
    src = np.zeros(64, np.uint8)
    t0 = time.perf_counter()
    for _ in range(n):
        amu.aload(src)
    issue_us = (time.perf_counter() - t0) * 1e6 / n
    amu.backend.advance(1.0)
    t0 = time.perf_counter()
    drained = 0
    while drained < n:
        if amu.getfin() >= 0:
            drained += 1
    fin_us = (time.perf_counter() - t0) * 1e6 / n
    _row("amu_issue", issue_us, f"n={n} outstanding=256")
    _row("amu_getfin", fin_us, f"n={n}")


# ---------------------------------------------------------------------------
# kernels (interpret-mode semantics timing; NOT hardware performance)
# ---------------------------------------------------------------------------

def bench_kernels() -> None:
    import jax
    import jax.numpy as jnp
    from repro.kernels.amu_matmul import amu_matmul
    from repro.kernels.decode_attention import decode_attention
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.mamba2 import ssd
    from repro.kernels.rwkv6 import wkv6

    rng = np.random.default_rng(0)

    def timeit(name, fn, *args, derived="", reps=3, **kw):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args, **kw))
        us = (time.perf_counter() - t0) * 1e6 / reps
        _row(name, us, derived + " [interpret-mode; semantics only]")

    x = jnp.asarray(rng.standard_normal((256, 512)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
    timeit("kernel_amu_matmul", amu_matmul, x, w, bm=128, bk=128, bn=128,
           derived="256x512x256 flops=" + str(2 * 256 * 512 * 256))

    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    timeit("kernel_flash_attention", flash_attention, q, k, k, causal=True,
           bq=128, bkv=128, derived="B1 H4/2 S256 D64")

    qd = jnp.asarray(rng.standard_normal((2, 8, 64)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((2, 1024, 2, 64)), jnp.float32)
    timeit("kernel_decode_attention", decode_attention, qd, kd, kd,
           valid_len=1000, bkv=256, derived="B2 H8/2 cache1024")

    r = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    wv = -jnp.exp(jnp.asarray(rng.standard_normal((1, 256, 2, 64))) - 2)
    u = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32) * 0.1
    timeit("kernel_wkv6", wkv6, r, r, r, wv, u, chunk=64,
           derived="B1 T256 H2 K64")

    xs = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    dts = jnp.abs(jnp.asarray(rng.standard_normal((1, 256, 2))))
    Bs = jnp.asarray(rng.standard_normal((1, 256, 64)), jnp.float32)
    timeit("kernel_ssd", ssd, xs, dts, jnp.linspace(0.5, 4, 2), Bs, Bs,
           jnp.ones(2), chunk=64, derived="B1 T256 H2 P64 N64")


# ---------------------------------------------------------------------------
# roofline table from the dry-run artifacts
# ---------------------------------------------------------------------------

def bench_roofline() -> None:
    root = Path(__file__).resolve().parent.parent
    for fname in ("dryrun_single.jsonl", "dryrun_multi.jsonl"):
        p = root / fname
        if not p.exists():
            _row("roofline_missing", 0.0, f"{fname} not found — run "
                 "python -m repro.launch.dryrun --all first")
            continue
        rows = {}
        for line in p.read_text().splitlines():
            if line.strip():
                r = json.loads(line)
                rows[(r["arch"], r["shape"], r["mesh"])] = r
        for r in rows.values():
            if r.get("status") == "skipped":
                _row("roofline_cell", 0.0,
                     f"{r['arch']}|{r['shape']}|{r['mesh']}|SKIPPED")
                continue
            if r.get("status") != "ok":
                _row("roofline_cell", 0.0,
                     f"{r['arch']}|{r['shape']}|{r['mesh']}|FAILED")
                continue
            us = r["step_time_lower_bound"] * 1e6
            _row("roofline_cell", us,
                 f"{r['arch']}|{r['shape']}|{r['mesh']}|"
                 f"bottleneck={r['bottleneck']}|"
                 f"t_comp={r['t_compute']*1e3:.2f}ms|"
                 f"t_mem={r['t_memory']*1e3:.2f}ms|"
                 f"t_coll={r['t_collective']*1e3:.2f}ms|"
                 f"useful_flops={r['useful_flops_frac']:.3f}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: sim sweeps + runtime overhead, "
                         "skip interpret-mode kernel timings")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a JSON array")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the obs_overhead bench's Chrome-trace "
                         "JSON (load in ui.perfetto.dev or feed to "
                         "tools/trace_report.py)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the obs_overhead bench's flat metrics "
                         "snapshot JSON")
    args = ap.parse_args(argv)

    _ROWS.clear()
    print("name,us_per_call,derived")
    bench_fig1_latency_sweep()
    bench_granularity_sweep()
    bench_outstanding_sweep()
    bench_paged_kv_sweep()
    bench_mixed_batch_sweep()
    bench_disagg_sweep()
    bench_prefix_reuse_sweep()
    bench_spec_decode_sweep()
    bench_slo_goodput_sweep()
    bench_obs_overhead(trace_out=args.trace_out,
                       metrics_out=args.metrics_out)
    bench_amu_runtime(n=2_000 if args.smoke else 20_000)
    if not args.smoke:
        bench_kernels()
    bench_roofline()

    if args.json:
        Path(args.json).write_text(json.dumps(_ROWS, indent=2) + "\n")


if __name__ == "__main__":
    main()

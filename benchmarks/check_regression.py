"""CI gate: diff a fresh BENCH_smoke.json against the committed baseline.

The ``paged_kv_sweep`` rows are fully deterministic (SimBackend virtual
clock), so any movement is a code change, not noise.  The gate fails
when the paged policy's decode throughput (1 / ``paged=...us/tok``) at
any swept oversubscription ratio drops more than ``--threshold``
(default 10%) below the committed baseline; improvements just print.

Usage::

    python benchmarks/check_regression.py BENCH_smoke.json \
        benchmarks/BENCH_baseline.json [--threshold 0.10]

Regenerate the baseline (after an intentional perf change) with::

    PYTHONPATH=src python benchmarks/run.py --smoke \
        --json benchmarks/BENCH_baseline.json
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path
from typing import Dict


def paged_rows(rows) -> Dict[float, Dict[str, float]]:
    """oversub -> parsed numeric fields of each paged_kv_sweep row."""
    out: Dict[float, Dict[str, float]] = {}
    for row in rows:
        if row.get("name") != "paged_kv_sweep":
            continue
        fields: Dict[str, float] = {}
        for key, val in re.findall(r"(\w+)=([-\d.]+)", row.get("derived", "")):
            fields[key] = float(val)
        if "oversub" in fields:
            out[fields["oversub"]] = fields
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", type=Path)
    ap.add_argument("baseline", type=Path)
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="max fractional throughput regression (default 10%)")
    args = ap.parse_args(argv)

    cur = paged_rows(json.loads(args.current.read_text()))
    base = paged_rows(json.loads(args.baseline.read_text()))
    if not base:
        print("FAIL: baseline has no paged_kv_sweep rows")
        return 1

    failed = False
    for oversub, b in sorted(base.items()):
        c = cur.get(oversub)
        if c is None:
            print(f"FAIL: oversub={oversub:g} row missing from current run")
            failed = True
            continue
        # throughput = 1 / us-per-token; regression = throughput drop
        b_tok = b["paged"]
        c_tok = c["paged"]
        change = b_tok / c_tok - 1.0          # >0: faster, <0: slower
        status = "OK"
        if change < -args.threshold:
            status = "FAIL"
            failed = True
        print(f"{status}: oversub={oversub:g} paged {b_tok:.2f} -> "
              f"{c_tok:.2f} us/tok ({change:+.1%} throughput), "
              f"speedup {b.get('speedup', 0):.2f} -> "
              f"{c.get('speedup', 0):.2f}")
    if failed:
        print(f"paged_kv_sweep throughput regressed beyond "
              f"{args.threshold:.0%} of the committed baseline")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())

"""CI gate: diff a fresh BENCH_smoke.json against the committed baselines.

Three deterministic gates (SimBackend virtual clocks and compile-only
dry-runs — any movement is a code change, not noise):

* ``paged_kv_sweep`` — fails when the paged policy's decode throughput
  (1 / ``paged=...us/tok``) at any swept oversubscription ratio drops
  more than ``--threshold`` below the committed baseline,
* ``prefix_reuse_sweep`` — fails when the TTFT speedup at any swept
  shared-traffic fraction drops more than ``--threshold`` below the
  baseline, or when the 50%-shared row falls under the 1.5x acceptance
  floor,
* ``slo_goodput_sweep`` — fails when the SLO-aware scheduler's
  interactive-goodput ratio over watermark-FIFO drops more than
  ``--threshold`` below the baseline at any swept oversubscription, or
  when the 4x-oversubscription row falls under the 1.2x acceptance
  floor; the same rows also carry a tail-latency gate —
  ``ttft_p99_slo`` (lower is better) must not regress beyond
  ``--threshold`` vs the baseline,
* ``spec_decode_sweep`` — fails when the verify-K decode-throughput
  speedup or mean accepted-K regresses on either traffic shape, when
  the repetitive 2x row drops below its 1.3x acceptance floor, or when
  an adversarial row collapses below 0.9x (mis-drafting must stay
  bounded by the verify surcharge);

* ``disagg_sweep`` — fails when any of the disaggregated-vs-fused
  ratios (``tpot_ratio`` / ``ttft_ratio`` / ``goodput_ratio``) at any
  swept oversubscription drops more than ``--threshold`` below the
  baseline, or when the 2x row falls under the absolute floors:
  inter-token latency must favour the split
  (``tpot_ratio >= 1.05``) and the split must keep at least half of
  fused goodput at matched device counts,
* ``obs_overhead`` — the telemetry observer-effect guard: fails when
  the tracer-disabled run's virtual-clock throughput (``paged_off``)
  drifts from the committed baseline's ``paged_kv_sweep oversub=2``
  row at all, when the tracer-enabled run degrades it more than
  ``OBS_OVERHEAD_MAX`` (in practice 0% — telemetry never touches the
  clock), or when any virtual-clock result differs between the three
  modes (``deterministic=0``),
* roofline (``--roofline docs/ROOFLINE.md``) — diffs the fresh
  ``roofline_cell`` rows against the committed roofline table and fails
  when any cell's bottleneck class flips or its step-time lower bound
  regresses (grows) more than ``--threshold``.

Usage::

    python benchmarks/check_regression.py BENCH_smoke.json \
        benchmarks/BENCH_baseline.json [--threshold 0.10] \
        [--roofline docs/ROOFLINE.md]

Regenerate the baseline (after an intentional perf change) with::

    PYTHONPATH=src python benchmarks/run.py --smoke \
        --json benchmarks/BENCH_baseline.json
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path
from typing import Dict, Tuple

#: prefix_reuse_sweep acceptance floor: TTFT speedup at 50% shared traffic.
PREFIX_FLOOR_AT_HALF = 1.5

#: slo_goodput_sweep acceptance floor: interactive goodput of the
#: SLO-aware scheduler over watermark-FIFO at 4x oversubscription.
SLO_FLOOR_AT_4X = 1.2

#: obs_overhead acceptance ceiling: virtual-clock throughput drift of
#: the tracer-enabled sim vs the committed paged_kv_sweep baseline.
OBS_OVERHEAD_MAX = 0.10

#: disagg_sweep acceptance floors at 2x oversubscription: the decode
#: device's mean inter-token latency must beat the fused engines'
#: (tpot_ratio — the interference-isolation claim), and the split must
#: keep at least this fraction of fused goodput at matched devices.
DISAGG_TPOT_FLOOR_AT_2X = 1.05
DISAGG_GOODPUT_FLOOR_AT_2X = 0.50

#: spec_decode_sweep acceptance floor: decode-throughput speedup of
#: verify-K speculation over single-step on repetitive traffic at 2x
#: request oversubscription (the draft-free speculation claim).
SPEC_FLOOR_AT_REPETITIVE = 1.3


def _parse_fields(derived: str) -> Dict[str, float]:
    fields: Dict[str, float] = {}
    for key, val in re.findall(r"(\w+)=([-\d.]+)", derived):
        try:
            fields[key] = float(val)
        except ValueError:
            pass
    return fields


def sweep_rows(rows, name: str, axis: str) -> Dict[float, Dict[str, float]]:
    """axis-value -> parsed numeric fields of each ``name`` row."""
    out: Dict[float, Dict[str, float]] = {}
    for row in rows:
        if row.get("name") != name:
            continue
        fields = _parse_fields(row.get("derived", ""))
        if axis in fields:
            out[fields[axis]] = fields
    return out


def check_sweep(cur_rows, base_rows, *, name: str, axis: str, metric: str,
                threshold: float, higher_is_better: bool = True) -> bool:
    """Generic per-row regression gate; returns True on failure."""
    cur = sweep_rows(cur_rows, name, axis)
    base = sweep_rows(base_rows, name, axis)
    if not base:
        print(f"WARN: baseline has no {name} rows (not gated)")
        return False
    failed = False
    for x, b in sorted(base.items()):
        c = cur.get(x)
        if c is None:
            print(f"FAIL: {name} {axis}={x:g} row missing from current run")
            failed = True
            continue
        if metric not in b or metric not in c:
            print(f"WARN: {name} {axis}={x:g} lacks {metric} (not gated)")
            continue
        bv, cv = b[metric], c[metric]
        if bv == 0 or cv == 0:
            print(f"WARN: {name} {axis}={x:g} {metric} is zero (not gated)")
            continue
        change = (cv / bv - 1.0) if higher_is_better else (bv / cv - 1.0)
        status = "OK"
        if change < -threshold:
            status = "FAIL"
            failed = True
        print(f"{status}: {name} {axis}={x:g} {metric} "
              f"{bv:.3f} -> {cv:.3f} ({change:+.1%})")
    return failed


def check_prefix_floor(cur_rows) -> bool:
    """Absolute acceptance: >= 1.5x TTFT at 50% shared-prefix traffic."""
    cur = sweep_rows(cur_rows, "prefix_reuse_sweep", "shared")
    row = cur.get(0.5)
    if row is None:
        print("FAIL: prefix_reuse_sweep has no shared=0.5 row")
        return True
    speedup = row.get("ttft_speedup", 0.0)
    ok = speedup >= PREFIX_FLOOR_AT_HALF
    print(f"{'OK' if ok else 'FAIL'}: prefix_reuse_sweep shared=0.5 "
          f"ttft_speedup={speedup:.3f} (floor {PREFIX_FLOOR_AT_HALF})")
    return not ok


def check_obs_overhead(cur_rows, base_rows) -> bool:
    """Telemetry observer-effect guard: tracing-disabled must match the
    committed ``paged_kv_sweep oversub=2`` baseline exactly (the sim is
    deterministic), tracing-enabled must degrade its virtual-clock
    throughput < ``OBS_OVERHEAD_MAX``, and all three modes must agree
    on every virtual result (``deterministic=1``)."""
    rows = [r for r in cur_rows if r.get("name") == "obs_overhead"]
    if not rows:
        print("FAIL: current run has no obs_overhead row")
        return True
    f = _parse_fields(rows[0].get("derived", ""))
    det = f.get("deterministic", 0.0) >= 1.0
    failed = not det
    ref = sweep_rows(base_rows, "paged_kv_sweep", "oversub") \
        .get(2.0, {}).get("paged")
    if ref:
        off_drift = abs(f.get("paged_off", 0.0) / ref - 1.0)
        on_drift = f.get("paged_on", float("inf")) / ref - 1.0
        # 2e-3 relative: both rows round us/token to two decimals, so
        # print rounding alone can move the ratio by ~1e-3 on a ~5us
        # value; anything beyond that is a real observer effect
        if off_drift > 2e-3:
            print(f"FAIL: obs_overhead tracer-disabled run perturbed the "
                  f"sim: paged_off={f.get('paged_off'):.3f} vs "
                  f"baseline {ref:.3f}")
            failed = True
        if on_drift > OBS_OVERHEAD_MAX:
            print(f"FAIL: obs_overhead tracer-enabled run degraded "
                  f"virtual throughput {on_drift:+.1%} "
                  f"(ceiling {OBS_OVERHEAD_MAX:.0%})")
            failed = True
    else:
        print("WARN: baseline has no paged_kv_sweep oversub=2 row "
              "(obs drift not gated)")
    print(f"{'FAIL' if failed else 'OK'}: obs_overhead "
          f"deterministic={int(det)} paged_off={f.get('paged_off', 0):.3f} "
          f"paged_on={f.get('paged_on', 0):.3f} "
          f"wall_frac={f.get('wall_frac', 0):.3f} (informational)")
    return failed


def check_disagg_floor(cur_rows) -> bool:
    """Absolute acceptance at 2x load: disaggregation must win
    inter-token latency (tpot_ratio >= floor) while keeping at least
    half of fused goodput at matched device counts."""
    cur = sweep_rows(cur_rows, "disagg_sweep", "oversub")
    row = cur.get(2.0)
    if row is None:
        print("FAIL: disagg_sweep has no oversub=2 row")
        return True
    tpot = row.get("tpot_ratio", 0.0)
    good = row.get("goodput_ratio", 0.0)
    ok = tpot >= DISAGG_TPOT_FLOOR_AT_2X and \
        good >= DISAGG_GOODPUT_FLOOR_AT_2X
    print(f"{'OK' if ok else 'FAIL'}: disagg_sweep oversub=2 "
          f"tpot_ratio={tpot:.3f} (floor {DISAGG_TPOT_FLOOR_AT_2X}) "
          f"goodput_ratio={good:.3f} (floor {DISAGG_GOODPUT_FLOOR_AT_2X})")
    return not ok


def _traffic_rows(rows, name: str, traffic: str):
    """Rows of ``name`` carrying ``traffic=<shape>`` in their derived
    string (the shape is non-numeric, so ``_parse_fields`` skips it and
    the two sweeps would collide on the oversub axis otherwise)."""
    return [r for r in rows if r.get("name") == name
            and f"traffic={traffic} " in r.get("derived", "")]


def check_spec_floor(cur_rows) -> bool:
    """Absolute acceptance: >= 1.3x decode throughput on repetitive
    traffic at 2x oversubscription, and the adversarial rows must not
    collapse (mis-drafting is bounded by the verify surcharge, never
    catastrophic)."""
    cur = sweep_rows(_traffic_rows(cur_rows, "spec_decode_sweep",
                                   "repetitive"),
                     "spec_decode_sweep", "oversub")
    row = cur.get(2.0)
    if row is None:
        print("FAIL: spec_decode_sweep has no repetitive oversub=2 row")
        return True
    speedup = row.get("thr_speedup", 0.0)
    ok = speedup >= SPEC_FLOOR_AT_REPETITIVE
    print(f"{'OK' if ok else 'FAIL'}: spec_decode_sweep repetitive "
          f"oversub=2 thr_speedup={speedup:.3f} "
          f"(floor {SPEC_FLOOR_AT_REPETITIVE})")
    adv = sweep_rows(_traffic_rows(cur_rows, "spec_decode_sweep",
                                   "adversarial"),
                     "spec_decode_sweep", "oversub")
    for x, r in sorted(adv.items()):
        s = r.get("thr_speedup", 0.0)
        if s < 0.9:
            print(f"FAIL: spec_decode_sweep adversarial oversub={x:g} "
                  f"thr_speedup={s:.3f} collapsed below 0.9")
            ok = False
    return not ok


def check_slo_floor(cur_rows) -> bool:
    """Absolute acceptance: >= 1.2x interactive goodput at 4x load."""
    cur = sweep_rows(cur_rows, "slo_goodput_sweep", "oversub")
    row = cur.get(4.0)
    if row is None:
        print("FAIL: slo_goodput_sweep has no oversub=4 row")
        return True
    ratio = row.get("goodput_ratio", 0.0)
    ok = ratio >= SLO_FLOOR_AT_4X
    print(f"{'OK' if ok else 'FAIL'}: slo_goodput_sweep oversub=4 "
          f"goodput_ratio={ratio:.3f} (floor {SLO_FLOOR_AT_4X})")
    return not ok


# -- roofline gate -----------------------------------------------------------

def roofline_table(md_path: Path) -> Dict[Tuple[str, str, str],
                                          Tuple[str, float]]:
    """Parse docs/ROOFLINE.md: (arch, shape, mesh) -> (bottleneck, us)."""
    out: Dict[Tuple[str, str, str], Tuple[str, float]] = {}
    for line in md_path.read_text().splitlines():
        cols = [c.strip() for c in line.strip().strip("|").split("|")]
        if len(cols) < 9 or cols[0] in ("arch", "---"):
            continue
        m = re.match(r"([-\d.]+)\s*ms", cols[7])
        if m is None:
            continue
        out[(cols[0], cols[1], cols[2])] = (cols[3], float(m.group(1)) * 1e3)
    return out


def roofline_cells(rows) -> Dict[Tuple[str, str, str], Tuple[str, float]]:
    """Parse roofline_cell bench rows: key -> (bottleneck, us)."""
    out: Dict[Tuple[str, str, str], Tuple[str, float]] = {}
    for row in rows:
        if row.get("name") != "roofline_cell":
            continue
        parts = row.get("derived", "").split("|")
        if len(parts) < 4 or parts[3] in ("SKIPPED", "FAILED"):
            continue
        m = re.match(r"bottleneck=(\w+)", parts[3])
        if m is None:
            continue
        out[(parts[0], parts[1], parts[2])] = (m.group(1),
                                               float(row["us_per_call"]))
    return out


def check_roofline(cur_rows, md_path: Path, threshold: float) -> bool:
    """Fail when a cell's bottleneck class flips or its step lower
    bound regresses (grows) beyond ``threshold`` vs the committed
    table.  Cells absent from the fresh run (dryrun artifacts not
    rebuilt in this job) are not gated; cells absent from the table
    are new and just print."""
    table = roofline_table(md_path)
    cells = roofline_cells(cur_rows)
    if not table:
        print(f"FAIL: no roofline rows parsed from {md_path}")
        return True
    if not cells:
        print("WARN: current run has no roofline_cell rows (not gated)")
        return False
    failed = False
    flips = regress = 0
    for key, (bneck, us) in sorted(cells.items()):
        ref = table.get(key)
        if ref is None:
            print(f"NEW: roofline cell {'|'.join(key)} "
                  f"bottleneck={bneck} {us:.0f}us")
            continue
        ref_bneck, ref_us = ref
        if bneck != ref_bneck:
            print(f"FAIL: roofline {'|'.join(key)} bottleneck flipped "
                  f"{ref_bneck} -> {bneck}")
            failed = True
            flips += 1
        change = us / ref_us - 1.0
        if change > threshold:
            print(f"FAIL: roofline {'|'.join(key)} step lower bound "
                  f"{ref_us:.0f} -> {us:.0f}us ({change:+.1%})")
            failed = True
            regress += 1
    print(f"roofline: {len(cells)} cells checked vs {md_path} "
          f"({flips} bottleneck flips, {regress} bound regressions)")
    return failed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", type=Path)
    ap.add_argument("baseline", type=Path)
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="max fractional regression (default 10%%)")
    ap.add_argument("--roofline", type=Path, default=None, metavar="MD",
                    help="also gate roofline_cell rows against this "
                         "committed docs/ROOFLINE.md table")
    args = ap.parse_args(argv)

    cur = json.loads(args.current.read_text())
    base = json.loads(args.baseline.read_text())

    failed = False
    if not sweep_rows(base, "paged_kv_sweep", "oversub"):
        print("FAIL: baseline has no paged_kv_sweep rows")
        failed = True
    # throughput = 1 / us-per-token: lower 'paged' is better
    failed |= check_sweep(cur, base, name="paged_kv_sweep", axis="oversub",
                          metric="paged", threshold=args.threshold,
                          higher_is_better=False)
    failed |= check_sweep(cur, base, name="prefix_reuse_sweep",
                          axis="shared", metric="ttft_speedup",
                          threshold=args.threshold)
    failed |= check_prefix_floor(cur)
    failed |= check_sweep(cur, base, name="slo_goodput_sweep",
                          axis="oversub", metric="goodput_ratio",
                          threshold=args.threshold)
    # tail-latency gate: p99 interactive TTFT under the SLO scheduler
    # must not grow beyond threshold (lower is better)
    failed |= check_sweep(cur, base, name="slo_goodput_sweep",
                          axis="oversub", metric="ttft_p99_slo",
                          threshold=args.threshold,
                          higher_is_better=False)
    failed |= check_slo_floor(cur)
    # disaggregation gates: per-row regression on all three ratios plus
    # the absolute TPOT/goodput floors at 2x (matched device counts)
    failed |= check_sweep(cur, base, name="disagg_sweep", axis="oversub",
                          metric="tpot_ratio", threshold=args.threshold)
    failed |= check_sweep(cur, base, name="disagg_sweep", axis="oversub",
                          metric="ttft_ratio", threshold=args.threshold)
    failed |= check_sweep(cur, base, name="disagg_sweep", axis="oversub",
                          metric="goodput_ratio",
                          threshold=args.threshold)
    failed |= check_disagg_floor(cur)
    # speculation gates: per-traffic-shape regression on the speedup
    # and mean accepted-K, plus the absolute repetitive floor at 2x
    for shape in ("repetitive", "adversarial"):
        failed |= check_sweep(_traffic_rows(cur, "spec_decode_sweep", shape),
                              _traffic_rows(base, "spec_decode_sweep",
                                            shape),
                              name="spec_decode_sweep", axis="oversub",
                              metric="thr_speedup",
                              threshold=args.threshold)
    failed |= check_sweep(_traffic_rows(cur, "spec_decode_sweep",
                                        "repetitive"),
                          _traffic_rows(base, "spec_decode_sweep",
                                        "repetitive"),
                          name="spec_decode_sweep", axis="oversub",
                          metric="mean_accepted_k",
                          threshold=args.threshold)
    failed |= check_spec_floor(cur)
    failed |= check_obs_overhead(cur, base)
    if args.roofline is not None:
        failed |= check_roofline(cur, args.roofline, args.threshold)
    if failed:
        print("benchmark gates failed against the committed baselines")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
